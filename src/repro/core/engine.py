"""Jitted stacked swarm engine: the whole P2P-SL round as ONE compiled program.

The paper's loop (§3.1) — `sync_every` local steps, peer exchange, 80 %-
validation gated commit — was previously host-simulated as a Python loop over
nodes: every sync unstacked N param copies, ran per-node ``eval_fn`` with
``float(...)`` device round-trips, and merged through an unfused mix + where.
This module compiles the round end-to-end over **stacked pytrees** (leading
node axis N):

  local steps   ``jax.vmap`` of the user train step over the node axis,
                ``jax.lax.scan`` over the ``sync_every`` time axis;
  propose       mixing-matrix contraction (host backend, `merge_impl`) or
                mesh collectives (gossip backend, `core.gossip`);
  gate          in-graph validation metrics for local AND merged params
                (``jax.vmap`` of a traceable ``eval_fn``) → per-node accept
                bits — no host scalar sync anywhere in the round;
  commit        `kernels.fused_merge.fused_merge_tree` with a full mixing
                matrix: the Pallas kernel fuses contraction-over-nodes and
                gating into one VMEM pass per leaf (interpret-mode on CPU).

API
---
``SwarmEngine(cfg, train_step_fn, eval_fn, *, data_sizes, backend, ...)``

  * ``engine.round(params, opt_state, batches, val, active, step0)``
      one jitted round: ``[T, N, ...]`` batches → T vmapped local steps +
      propose + gate + fused commit. ``(params, opt_state)`` are donated, so
      the round updates buffers in place.
  * ``engine.run_rounds(params, opt_state, batches, val, active, step0)``
      ``jax.lax.scan`` driver over ``[R, T, N, ...]`` batches: R full rounds
      with zero host round-trips between them. Returns per-round train metrics
      and sync logs (gates / metric_local / metric_merged, ``[R, N]``).
  * ``engine.run_local(params, opt_state, batches, step0)``
      sync-free local training over ``[S, N, ...]`` batches (isolated
      baselines, remainder steps).
  * ``engine.propose(stacked, active, fishers)`` / ``engine.sync(...)``
      the pure pieces, reused by `SwarmLearner` (host) and
      `launch.train.make_swarm_sync_step` (SPMD gossip backend).

``train_step_fn(params, opt_state, batch, step) -> (params, opt_state,
metrics)`` and ``eval_fn(params, val) -> scalar in [0, 1]`` must be
jax-traceable; arbitrary host callables stay on the `SwarmLearner` slow path,
which still shares `propose_merge` / `host_commit` below.

Roofline
--------
The fused commit is memory-bound: for P stacked parameters the kernel moves
2N·P·4 bytes (read the [N, BLOCK] tile once per column block, write N rows)
— on TPU v5e (819 GB/s) that is ~9.8 µs per 10⁶ f32 params at N = 4, vs the
unfused mix (N·P in + N·P out) plus where (3N·P) of the XLA pair. Note the
gate forces the candidate to be materialized anyway (its validation metric
is part of the round), so the fused commit re-contracts W·θ rather than
re-reading candidate+local (2N·P vs 3N·P moved — the kernel also wins by
skipping the second mix output). Everything else in the round (vmapped train
steps) is compute-bound, so a round's wall time approaches T × (single-node
step time) on hardware with N-way parallelism along the node axis.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SwarmConfig
import repro.core.topology as topo
from repro.core import merge_impl as merge_lib
from repro.core.lora import combine, split_adapters
from repro.kernels.fused_merge import DEFAULT_BLOCK, fused_merge_tree


def default_interpret() -> bool:
    """Pallas interpret mode when no TPU is attached (validation mode)."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# pure building blocks (shared by engine, SwarmLearner, and SPMD paths)
# ---------------------------------------------------------------------------

def mixing_matrix(cfg: SwarmConfig, data_sizes: Sequence[float],
                  active: Optional[Sequence[bool]] = None) -> np.ndarray:
    """Host-side (numpy) mixing matrix for the configured topology."""
    weights = topo.fedavg_weights(data_sizes) if cfg.merge == "fedavg" else None
    return topo.build_matrix(cfg.topology, cfg.n_nodes,
                             weights=weights, self_weight=cfg.self_weight,
                             active=active)


def active_weights(data_sizes, active=None) -> np.ndarray:
    """FedAvg weights zeroed + renormalized over the active membership.

    Departed nodes must not leak into fisher/gradmatch merges with full
    dataset weight — their mass is redistributed over the survivors.
    """
    w = np.asarray(data_sizes, np.float64)
    if active is not None:
        w = w * np.asarray(active, np.float64)
    s = w.sum()
    if s <= 0:  # nobody active: uniform (downstream gates reject everything)
        return np.full(len(w), 1.0 / len(w))
    return w / s


def active_weights_traced(data_sizes, active) -> jnp.ndarray:
    """In-graph version of :func:`active_weights` (active may be traced)."""
    w = jnp.asarray(data_sizes, jnp.float32) * active.astype(jnp.float32)
    s = w.sum()
    n = w.shape[0]
    return jnp.where(s > 0, w / jnp.where(s > 0, s, 1.0), jnp.full((n,), 1.0 / n))


def mask_fishers(fishers, active):
    """Zero departed nodes' Fisher mass so their stale params can't enter
    fisher/gradmatch merges. The single implementation of that invariant —
    both SwarmLearner.sync and SwarmEngine.propose call it (host bools or
    traced masks)."""
    a = jnp.asarray(active)

    def one(f):
        if f is None:
            return None
        return f * a.astype(f.dtype).reshape((f.shape[0],) + (1,) * (f.ndim - 1))

    return jax.tree.map(one, fishers, is_leaf=lambda v: v is None)


def dynamic_matrix_traced(base, active) -> jnp.ndarray:
    """In-graph `topology.dynamic_matrix`: mask absent senders, renormalize
    rows, absent/isolated rows fall back to identity (keep own params)."""
    base = jnp.asarray(base, jnp.float32)
    n = base.shape[0]
    a = jnp.asarray(active).astype(jnp.float32)
    W = base * a[None, :]
    rows = W.sum(1, keepdims=True)
    W = jnp.where(rows > 0, W / jnp.where(rows > 0, rows, 1.0), 0.0)
    eye = jnp.eye(n, dtype=jnp.float32)
    W = jnp.where(a[:, None] > 0, W, eye)   # absent nodes keep their params
    rows = W.sum(1, keepdims=True)
    return jnp.where(rows > 0, W, eye)      # fully-isolated active rows too


def propose_merge(stacked, cfg: SwarmConfig, W, *, fishers=None, weights=None):
    """Merge candidate for every node. Honors lora_only payload selection."""
    if cfg.lora_only:
        adapters, base = split_adapters(stacked)
        merged_adapters = merge_lib.merge(
            adapters, cfg.merge if cfg.merge in ("fisher", "gradmatch") else "fedavg",
            W=W, fishers=split_adapters(fishers)[0] if fishers is not None else None,
            weights=weights)
        return combine(merged_adapters, base)
    method = cfg.merge if cfg.merge in ("fisher", "gradmatch") else "fedavg"
    return merge_lib.merge(stacked, method, W=W, fishers=fishers, weights=weights)


def gate_decisions(metric_merged, metric_local, threshold: float,
                   mode: str = "relative"):
    """Per-node accept bits. `relative`: merged ≥ thr × local (robust default);
    `absolute`: merged ≥ thr (the paper's literal 80% reading)."""
    m, l = jnp.asarray(metric_merged), jnp.asarray(metric_local)
    if mode == "relative":
        return m >= threshold * l
    return m >= threshold


def gated_commit(candidate, local, gates):
    """θ_i ← gate_i ? merged_i : local_i (leading node axis) — the unfused
    where-select, used when the candidate is not a W-row mix (fisher/gradmatch)."""
    g = jnp.asarray(gates)

    def one(c, l):
        if c is None or l is None:
            return c if l is None else l
        gb = g.reshape((g.shape[0],) + (1,) * (c.ndim - 1))
        return jnp.where(gb, c, l)

    return jax.tree.map(one, candidate, local, is_leaf=lambda x: x is None)


def host_commit(stacked, candidate, W, gates, cfg: SwarmConfig, *,
                block: int = DEFAULT_BLOCK, interpret: bool = False):
    """Commit via the fused Pallas kernel when the candidate is a W-row mix
    (mean/fedavg, any topology); fisher/gradmatch fall back to where-select.

    lora_only: only adapter leaves are re-merged; base leaves pass through
    local params bit-exactly (candidate base == local base by construction).
    """
    if cfg.merge in ("mean", "fedavg"):
        kw = dict(block=block, interpret=interpret)
        if cfg.lora_only:
            adapters, base = split_adapters(stacked)
            merged = fused_merge_tree(adapters, W, None, gates, **kw)
            return combine(merged, base)
        return fused_merge_tree(stacked, W, None, gates, **kw)
    return gated_commit(candidate, stacked, gates)


# jitted wrappers for the SwarmLearner host path (cfg hashes — frozen dataclass)

@functools.partial(jax.jit, static_argnames=("cfg",))
def _propose_jit(stacked, W, fishers, weights, cfg):
    return propose_merge(stacked, cfg, W, fishers=fishers, weights=weights)


def propose_host(stacked, cfg: SwarmConfig, W, *, fishers=None, weights=None):
    """One-call jitted propose (stack→mix fused by XLA; no eager dispatch)."""
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    return _propose_jit(stacked, jnp.asarray(W, jnp.float32), fishers, w, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "block", "interpret"))
def _commit_jit(stacked, candidate, W, gates, cfg, block, interpret):
    return host_commit(stacked, candidate, W, gates, cfg,
                       block=block, interpret=interpret)


def commit_host(stacked, candidate, W, gates, cfg: SwarmConfig, *,
                block: int = DEFAULT_BLOCK, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return _commit_jit(stacked, candidate, jnp.asarray(W, jnp.float32),
                       jnp.asarray(gates).astype(bool), cfg, block, interpret)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class SwarmEngine:
    """Compiled stacked swarm: vmapped local steps + in-graph gated sync.

    backend="host"    merge via mixing-matrix contraction, commit via the
                      fused Pallas kernel (N param copies on one device —
                      the paper-repro and benchmark path).
    backend="gossip"  merge via `core.gossip` mesh collectives (leading node
                      axis sharded over ``axis``); commit stays the in-graph
                      where-select, since the merged payload already lives on
                      each node's shard.
    """

    def __init__(self, cfg: SwarmConfig, train_step_fn: Optional[Callable],
                 eval_fn: Optional[Callable], *,
                 data_sizes: Optional[Sequence[float]] = None,
                 backend: str = "host", mesh=None, axis: Optional[str] = None,
                 param_specs=None, block: int = DEFAULT_BLOCK,
                 interpret: Optional[bool] = None):
        if backend not in ("host", "gossip"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "gossip" and (mesh is None or axis is None):
            raise ValueError("gossip backend needs mesh and axis")
        self.cfg = cfg
        self.backend = backend
        self.mesh, self.axis, self.param_specs = mesh, axis, param_specs
        self.block = block
        self.interpret = default_interpret() if interpret is None else interpret
        self.data_sizes = (np.ones(cfg.n_nodes) if data_sizes is None
                           else np.asarray(data_sizes, np.float64))
        self._vstep = (None if train_step_fn is None
                       else jax.vmap(train_step_fn, in_axes=(0, 0, 0, None)))
        self._veval = None if eval_fn is None else jax.vmap(eval_fn)
        self._base_W = mixing_matrix(cfg, self.data_sizes)
        self.spectral_gap = topo.spectral_gap(self._base_W)

        # jitted entry points; (params, opt_state) buffers are donated so a
        # round updates in place — callers must not reuse the inputs.
        self.round = jax.jit(self._round, donate_argnums=(0, 1))
        self.run_rounds = jax.jit(self._run_rounds, donate_argnums=(0, 1))
        self.run_local = jax.jit(self._run_local, donate_argnums=(0, 1))

    # -- local training ------------------------------------------------------

    def local_steps(self, params, opt_state, batches, step0):
        """scan over the leading [T] time axis of vmapped local steps."""
        def body(carry, batch):
            p, o, s = carry
            p, o, m = self._vstep(p, o, batch, s)
            return (p, o, s + 1), m

        init = (params, opt_state, jnp.asarray(step0, jnp.int32))
        (p, o, _), metrics = jax.lax.scan(body, init, batches)
        return p, o, metrics

    # -- propose -------------------------------------------------------------

    def propose(self, stacked, active=None, fishers=None):
        """Merge candidate for every node. Returns (candidate, W_or_None)."""
        if self.backend == "gossip":
            return self._propose_gossip(stacked, active, fishers), None
        n = self.cfg.n_nodes
        a = (jnp.ones((n,), bool) if active is None
             else jnp.asarray(active).astype(bool))
        W = dynamic_matrix_traced(self._base_W, a)
        w = active_weights_traced(self.data_sizes, a)
        if self.cfg.merge in ("fisher", "gradmatch") and fishers is None:
            fishers = jax.tree.map(jnp.ones_like, stacked)  # = SwarmLearner default
        if fishers is not None:
            fishers = mask_fishers(fishers, a)
        cand = propose_merge(stacked, self.cfg, W, fishers=fishers, weights=w)
        return cand, W

    def _propose_gossip(self, stacked, active, fishers):
        from repro.core import gossip
        from jax.sharding import PartitionSpec as P

        cfg, specs = self.cfg, self.param_specs
        weights = self.data_sizes / self.data_sizes.sum()
        if cfg.lora_only:
            payload, base = split_adapters(stacked)
            if specs is not None:
                specs = split_adapters(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]
            if fishers is not None:
                fishers = split_adapters(fishers)[0]
        else:
            payload, base = stacked, None

        if cfg.merge == "fisher":
            if fishers is None:
                raise ValueError("fisher merge needs fisher estimates")
            merged = gossip.fisher_gossip(payload, fishers, self.mesh,
                                          self.axis, inner_specs=specs)
        elif cfg.topology == "ring":
            merged = gossip.ring_gossip(payload, self.mesh, self.axis,
                                        self_weight=cfg.self_weight,
                                        inner_specs=specs)
        elif cfg.topology == "dynamic" or active is not None:
            # in-graph masking so a traced active mask works under jit too
            a = (jnp.ones((cfg.n_nodes,), bool) if active is None
                 else jnp.asarray(active).astype(bool))
            W = dynamic_matrix_traced(self._base_W, a)
            merged = gossip.matrix_gossip(payload, W, self.mesh, self.axis,
                                          inner_specs=specs)
        else:
            merged = gossip.fedavg_gossip(payload, weights, self.mesh,
                                          self.axis, inner_specs=specs)

        return combine(merged, base) if cfg.lora_only else merged

    # -- gated sync ----------------------------------------------------------

    def sync(self, params, val, active=None):
        """propose → in-graph validate → gate → fused commit. Pure/traceable."""
        n = self.cfg.n_nodes
        a = (jnp.ones((n,), bool) if active is None
             else jnp.asarray(active).astype(bool))
        candidate, W = self.propose(params, active)
        metric_local = jnp.where(a, self._veval(params, val), 1.0)
        metric_merged = jnp.where(a, self._veval(candidate, val), 0.0)
        gates = gate_decisions(metric_merged, metric_local,
                               self.cfg.val_threshold) & a
        if self.backend == "host":
            committed = host_commit(params, candidate, W, gates, self.cfg,
                                    block=self.block, interpret=self.interpret)
        else:
            committed = gated_commit(candidate, params, gates)
        return committed, {"gates": gates, "metric_local": metric_local,
                           "metric_merged": metric_merged}

    # -- jitted drivers ------------------------------------------------------

    def _round(self, params, opt_state, batches, val, active=None, step0=0):
        """T local steps + one gated sync — a single compiled program."""
        params, opt_state, train_metrics = self.local_steps(
            params, opt_state, batches, step0)
        params, log = self.sync(params, val, active)
        return params, opt_state, dict(log, train=train_metrics)

    def _run_rounds(self, params, opt_state, batches, val, active=None,
                    step0=0):
        """scan over R rounds of [R, T, N, ...] batches; no host round-trips."""
        t = jax.tree.leaves(batches)[0].shape[1]

        def body(carry, round_batches):
            p, o, s = carry
            p, o, tm = self.local_steps(p, o, round_batches, s)
            p, log = self.sync(p, val, active)
            return (p, o, s + t), (tm, log)

        init = (params, opt_state, jnp.asarray(step0, jnp.int32))
        (p, o, _), (train_metrics, logs) = jax.lax.scan(body, init, batches)
        return p, o, train_metrics, logs

    def _run_local(self, params, opt_state, batches, step0=0):
        """Sync-free local training over [S, N, ...] batches."""
        return self.local_steps(params, opt_state, batches, step0)
