"""Model-merging algorithms for swarm aggregation.

All merges operate on **stacked pytrees**: every leaf carries a leading node
axis N. This single representation serves both execution modes:

  * host-simulated swarm (paper repro, N param copies on one device),
  * SPMD swarm (leading axis sharded over the mesh's `node`/`pod` axis, where
    the einsum against the mixing matrix lowers to the gossip collectives).

Implemented merges (paper §2 taxonomy):
  mean / fedavg — arithmetic & dataset-size-weighted averaging (the paper's
                  own mechanism; weighting is folded into the mixing matrix)
  fisher        — diagonal-Fisher-weighted averaging (Matena & Raffel style;
                  cited by the paper as the principled upgrade)
  gradmatch     — uncertainty-based gradient matching (Daheim et al. [6]):
                  Fisher-preconditioned delta correction around a reference

`MergeStrategy` wraps each method as a traceable first-class object with
``init_stats / accumulate / propose`` hooks, so the compiled swarm engine can
carry per-node importance statistics through its round scan and hand the
commit to the fused Pallas kernel — no host round-trips for any method. The
function forms above remain the numerical ground truth; strategies call them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def stack_params(param_list):
    """[pytree]*N -> stacked pytree with leading node axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *param_list)


def unstack_params(stacked, n: int):
    return [jax.tree.map(lambda x, i=i: x[i], stacked) for i in range(n)]


def mix(stacked, W):
    """Apply mixing matrix: θ_i ← Σ_j W[i,j] θ_j  (the gossip round).

    W: [N, N] row-stochastic (jnp or np). Leaf dtype is preserved; the
    contraction runs in fp32 at HIGHEST precision so accelerator backends
    don't drop to bf16 passes (on TPU the default matmul precision would
    cost ~3 decimal digits on every merge).
    """
    Wj = jnp.asarray(W, jnp.float32)

    def one(x):
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        out = jax.lax.dot(Wj, flat, precision=jax.lax.Precision.HIGHEST)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked)


def fisher_merge(stacked, fishers, eps: float = 1e-8):
    """θ* = Σ_i F_i ⊙ θ_i / Σ_i F_i, broadcast back to every node.

    fishers: stacked pytree of diagonal Fisher estimates (same structure).
    """
    def one(x, f):
        xf = x.astype(jnp.float32)
        ff = f.astype(jnp.float32) + eps
        merged = (ff * xf).sum(0) / ff.sum(0)
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked, fishers)


def gradmatch_merge(stacked, fishers, weights: Optional[jnp.ndarray] = None,
                    eps: float = 1e-8):
    """Uncertainty-based gradient matching (arXiv:2310.12808, simplified).

    Around the weighted mean θ̄, corrects each delta by its Fisher
    preconditioner:  θ* = θ̄ + Σ_i w_i (F_i/F̄ - 1) ⊙ (θ_i - θ̄) where
    F̄ = Σ w_i F_i. Reduces to FedAvg when all Fishers are equal.
    """
    n = jax.tree.leaves(stacked)[0].shape[0]
    w = jnp.full((n,), 1.0 / n) if weights is None else jnp.asarray(weights, jnp.float32)

    def one(x, f):
        xf = x.astype(jnp.float32)
        ff = f.astype(jnp.float32) + eps
        wb = w.reshape((n,) + (1,) * (x.ndim - 1))
        mean = (wb * xf).sum(0)
        fbar = (wb * ff).sum(0)
        corr = (wb * (ff / fbar - 1.0) * (xf - mean)).sum(0)
        merged = mean + corr
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked, fishers)


def topo_weighted_merge(stacked, fishers, rows, eps: float = 1e-8):
    """Topology-restricted importance-weighted merge (per-row ratio):

        θ*_i = Σ_j rows[i,j]·(F_j+eps)⊙θ_j / Σ_j rows[i,j]·(F_j+eps)

    ``rows`` [N, N] ≥ 0 carries the graph structure: ring/dynamic swarms pass
    their (possibly traced, membership-masked) mixing rows so each node only
    merges graph-neighbour contributions. Uniform rows cancel in the ratio —
    the full-topology case reduces to :func:`fisher_merge`; rows of dataset
    weights reduce to the gradmatch weighted-fisher identity. This is the
    numerical ground truth the fused Pallas ``imp`` kernel re-contracts.
    """
    R = jnp.asarray(rows, jnp.float32)

    def one(x, f):
        n = x.shape[0]
        xf = x.astype(jnp.float32).reshape(n, -1)
        ff = f.astype(jnp.float32).reshape(n, -1) + eps
        num = jax.lax.dot(R, ff * xf, precision=jax.lax.Precision.HIGHEST)
        den = jax.lax.dot(R, ff, precision=jax.lax.Precision.HIGHEST)
        out = num / jnp.maximum(den, 1e-30)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked, fishers)


def mask_fishers(fishers, active):
    """Zero departed nodes' Fisher mass so their stale params can't enter
    fisher/gradmatch merges. The single implementation of that invariant —
    every path reaches it through `MergeStrategy.finalize_mass` (host bools
    or traced masks)."""
    a = jnp.asarray(active)

    def one(f):
        if f is None:
            return None
        return f * a.astype(f.dtype).reshape((f.shape[0],) + (1,) * (f.ndim - 1))

    return jax.tree.map(one, fishers, is_leaf=lambda v: v is None)


def merge(stacked, method: str, *, W=None, fishers=None, weights=None):
    if method in ("mean", "fedavg"):
        if W is None:
            raise ValueError("mean/fedavg merges need a mixing matrix W")
        return mix(stacked, W)
    if method == "fisher":
        if fishers is None:
            raise ValueError("fisher merge needs fisher estimates")
        return fisher_merge(stacked, fishers)
    if method == "gradmatch":
        if fishers is None:
            raise ValueError("gradmatch merge needs fisher estimates")
        return gradmatch_merge(stacked, fishers, weights)
    raise ValueError(f"unknown merge {method!r}")


# ---------------------------------------------------------------------------
# MergeStrategy: the traceable first-class merge abstraction
# ---------------------------------------------------------------------------

class MergeStrategy:
    """Traceable merge strategy: ``init_stats`` → ``accumulate`` → ``propose``.

    The engine threads ``stats`` (a stacked pytree of per-node importance
    accumulators, or None) through its compiled round scan:

      * ``init_stats(stacked)``     zero accumulators matching the params
        (None for methods that need no statistics);
      * ``accumulate(stats, old, new, step)`` per-local-step in-graph update;
      * ``fishers(stats)``          finalize accumulators into the diagonal
        importance estimates the merge consumes;
      * ``propose(stacked, W, weights=, fishers=)`` →
        ``(candidate, W_commit, imp)``: the merge candidate for every node,
        plus the row-weight matrix and optional per-leaf importance pytree
        the fused Pallas commit re-contracts with. ``imp is None`` means the
        candidate is a plain W-row mix (mean/fedavg).

    Everything is pure jax — a strategy can run inside ``jit``/``scan``/
    ``shard_map`` with traced inputs. Candidates are computed by the module's
    function forms (``mix`` / ``fisher_merge`` / ``gradmatch_merge``) so the
    strategy path is numerically identical to ``merge(...)``.
    """

    method = "mean"
    uses_stats = False
    #: mass floor shared by every weighted-merge realization (host ratio,
    #: fused kernel imp, mesh psum/ppermute/gathered schedules and their
    #: q8 EF forms) — dispatch reads it off the strategy unconditionally
    eps = 1e-8

    def init_stats(self, stacked):
        """Per-node importance accumulators (None: method needs none)."""
        return None

    def accumulate(self, stats, old_params, new_params, step):
        """In-graph per-step stats update. Default: no-op."""
        return stats

    def accumulate_grads(self, stats, grads, step):
        """True-Fisher accumulation: consume exact per-step gradients (the
        opt-in ``train_step_fn`` 4-tuple signature returns them) instead of
        the Δθ² proxy. Default: no-op."""
        return stats

    def fishers(self, stats):
        """Finalize accumulators into diagonal importance estimates."""
        return stats

    def gossip_mass(self, fishers, weights):
        """Per-node importance mass for the collective (psum) realization —
        the one place any weight-folding identity lives for the SPMD path."""
        return fishers

    def finalize_mass(self, fishers, active=None):
        """Mask-then-finalize, in that order: a departed node's (possibly
        huge) stale mass must be zeroed BEFORE normalization, or it drags
        the normalization mean and drowns the survivors in the eps floor.
        Every merge path (engine host, engine gossip, SwarmLearner) calls
        this instead of hand-sequencing the two steps."""
        if fishers is None:
            return None
        if active is not None:
            fishers = mask_fishers(fishers, active)
        return self.fishers(fishers)

    def topo_rows(self, W, weights=None):
        """Per-row contribution weights for a topology-restricted merge
        (``rows=`` of :func:`topo_weighted_merge`). None: method is already
        row-structured (mix) or has no restricted form."""
        return None

    def propose(self, stacked, W, *, weights=None, fishers=None, rows=None):
        raise NotImplementedError


class MixStrategy(MergeStrategy):
    """mean / fedavg: candidate is the mixing-matrix contraction; the fused
    commit re-contracts the same W rows (no importance weights)."""

    def __init__(self, method: str = "fedavg"):
        self.method = method

    def propose(self, stacked, W, *, weights=None, fishers=None, rows=None):
        return mix(stacked, W), W, None


class FisherStrategy(MergeStrategy):
    """Diagonal-Fisher-weighted merging with in-graph mass accumulation.

    Without an explicit Fisher (squared-gradient) estimate, the accumulator
    is a decayed sum of squared parameter deltas: F ← γF + (θ_{t+1} − θ_t)².
    Under SGD-like updates this is lr²·ĝ² — a curvature proxy whose uniform
    scale cancels in the merge ratio Σ F_i θ_i / Σ F_i, so it needs no loss
    re-evaluation or extra backward pass inside the compiled round. Caveat:
    under adaptive optimizers (AdamW) the per-step delta is ~lr regardless
    of gradient scale, so the proxy flattens toward uniform and the merge
    approaches fedavg; pass exact squared-gradient estimates through the
    explicit ``fishers=`` channel when curvature weighting matters (see the
    ROADMAP true-Fisher accumulation hook).
    """

    method = "fisher"
    uses_stats = True

    def __init__(self, decay: float = 0.95, eps: float = 1e-8):
        self.decay = decay
        self.eps = eps

    def init_stats(self, stacked):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), stacked)

    def accumulate(self, stats, old_params, new_params, step):
        def one(s, po, pn):
            d = (pn - po).astype(jnp.float32)
            return self.decay * s + d * d

        return jax.tree.map(one, stats, old_params, new_params)

    def accumulate_grads(self, stats, grads, step):
        """Exact diagonal-Fisher mass from per-step gradients: F ← γF + g²
        (the ROADMAP true-Fisher hook — same decayed-sum shape as the Δθ²
        proxy, but scale-correct under adaptive optimizers)."""
        def one(s, g):
            gf = g.astype(jnp.float32)
            return self.decay * s + gf * gf

        return jax.tree.map(one, stats, grads)

    def fishers(self, stats):
        """Normalize accumulated mass to a global mean of 1. The merge ratio
        is scale-free, so this changes nothing when mass is already O(1) —
        it only keeps the lr²-scaled Δθ² proxy from drowning in the eps
        floor (tiny lr would otherwise collapse the merge to a uniform mean
        and re-admit `mask_fishers`-zeroed departed nodes)."""
        leaves = jax.tree.leaves(stats)
        total = sum(leaf.sum() for leaf in leaves)
        count = sum(leaf.size for leaf in leaves)
        mean = total / count
        scale = jnp.where(mean > 0, 1.0 / jnp.maximum(mean, 1e-30), 1.0)
        return jax.tree.map(lambda leaf: leaf * scale, stats)

    def _imp(self, stacked, fishers, weights):
        """Per-leaf importance for the fused commit: c_j·(F_j + eps)."""
        return jax.tree.map(lambda f: f.astype(jnp.float32) + self.eps, fishers)

    def _rows(self, n, weights):
        return jnp.ones((n, n), jnp.float32)

    def topo_rows(self, W, weights=None):
        """Graph-restricted fisher: contribution weights ARE the mixing rows,
        so only graph neighbours enter  Σ_j W[i,j]F_jθ_j / Σ_j W[i,j]F_j.
        Uniform full-topology rows cancel in the ratio (≡ global fisher)."""
        return jnp.asarray(W, jnp.float32)

    def propose(self, stacked, W, *, weights=None, fishers=None, rows=None):
        if fishers is None:
            fishers = jax.tree.map(jnp.ones_like, stacked)
        n = jax.tree.leaves(stacked)[0].shape[0]
        if rows is not None:   # ring/dynamic: per-row neighbour-restricted
            candidate = topo_weighted_merge(stacked, fishers, rows,
                                            eps=self.eps)
            return candidate, rows, self._imp(stacked, fishers, weights)
        candidate = self._merge(stacked, fishers, weights)
        return candidate, self._rows(n, weights), self._imp(stacked, fishers,
                                                            weights)

    def _merge(self, stacked, fishers, weights):
        return fisher_merge(stacked, fishers, eps=self.eps)


class GradMatchStrategy(FisherStrategy):
    """Uncertainty-based gradient matching. Algebraically
    θ* = θ̄ + Σ w(F/F̄ − 1)(θ − θ̄) = Σ w_j F_j θ_j / Σ w_j F_j — a
    dataset-weighted Fisher ratio — so the fused commit reuses the
    importance-weighted kernel with w_j folded into the row weights."""

    method = "gradmatch"

    def _rows(self, n, weights):
        w = (jnp.full((n,), 1.0 / n, jnp.float32) if weights is None
             else jnp.asarray(weights, jnp.float32))
        return jnp.broadcast_to(w[None, :], (n, n))

    def topo_rows(self, W, weights=None):
        """Graph-restricted gradmatch: dataset weights folded into the
        neighbour rows — c_ij = W[i,j]·w_j in the weighted-fisher ratio."""
        Wj = jnp.asarray(W, jnp.float32)
        if weights is None:
            return Wj
        return Wj * jnp.asarray(weights, jnp.float32)[None, :]

    def _merge(self, stacked, fishers, weights):
        return gradmatch_merge(stacked, fishers, weights, eps=self.eps)

    def gossip_mass(self, fishers, weights):
        """Fold w_j into the mass so `fisher_gossip`'s two psums realize the
        weighted ratio — the identity's single home on the SPMD path."""
        w = jnp.asarray(weights, jnp.float32)

        def one(f):
            return f * w.reshape((f.shape[0],) + (1,) * (f.ndim - 1))

        return jax.tree.map(one, fishers)


def get_strategy(cfg) -> MergeStrategy:
    """SwarmConfig → MergeStrategy (the single merge-method dispatch)."""
    method = cfg.merge
    if method in ("mean", "fedavg"):
        return MixStrategy(method)
    decay = getattr(cfg, "fisher_decay", 0.95)
    if method == "fisher":
        return FisherStrategy(decay=decay)
    if method == "gradmatch":
        return GradMatchStrategy(decay=decay)
    raise ValueError(f"unknown merge {method!r}")
