"""Model-merging algorithms for swarm aggregation.

All merges operate on **stacked pytrees**: every leaf carries a leading node
axis N. This single representation serves both execution modes:

  * host-simulated swarm (paper repro, N param copies on one device),
  * SPMD swarm (leading axis sharded over the mesh's `node`/`pod` axis, where
    the einsum against the mixing matrix lowers to the gossip collectives).

Implemented merges (paper §2 taxonomy):
  mean / fedavg — arithmetic & dataset-size-weighted averaging (the paper's
                  own mechanism; weighting is folded into the mixing matrix)
  fisher        — diagonal-Fisher-weighted averaging (Matena & Raffel style;
                  cited by the paper as the principled upgrade)
  gradmatch     — uncertainty-based gradient matching (Daheim et al. [6]):
                  Fisher-preconditioned delta correction around a reference
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def stack_params(param_list):
    """[pytree]*N -> stacked pytree with leading node axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *param_list)


def unstack_params(stacked, n: int):
    return [jax.tree.map(lambda x, i=i: x[i], stacked) for i in range(n)]


def mix(stacked, W):
    """Apply mixing matrix: θ_i ← Σ_j W[i,j] θ_j  (the gossip round).

    W: [N, N] row-stochastic (jnp or np). Leaf dtype is preserved; the
    contraction runs in fp32 at HIGHEST precision so accelerator backends
    don't drop to bf16 passes (on TPU the default matmul precision would
    cost ~3 decimal digits on every merge).
    """
    Wj = jnp.asarray(W, jnp.float32)

    def one(x):
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        out = jax.lax.dot(Wj, flat, precision=jax.lax.Precision.HIGHEST)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked)


def fisher_merge(stacked, fishers, eps: float = 1e-8):
    """θ* = Σ_i F_i ⊙ θ_i / Σ_i F_i, broadcast back to every node.

    fishers: stacked pytree of diagonal Fisher estimates (same structure).
    """
    def one(x, f):
        xf = x.astype(jnp.float32)
        ff = f.astype(jnp.float32) + eps
        merged = (ff * xf).sum(0) / ff.sum(0)
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked, fishers)


def gradmatch_merge(stacked, fishers, weights: Optional[jnp.ndarray] = None,
                    eps: float = 1e-8):
    """Uncertainty-based gradient matching (arXiv:2310.12808, simplified).

    Around the weighted mean θ̄, corrects each delta by its Fisher
    preconditioner:  θ* = θ̄ + Σ_i w_i (F_i/F̄ - 1) ⊙ (θ_i - θ̄) where
    F̄ = Σ w_i F_i. Reduces to FedAvg when all Fishers are equal.
    """
    n = jax.tree.leaves(stacked)[0].shape[0]
    w = jnp.full((n,), 1.0 / n) if weights is None else jnp.asarray(weights, jnp.float32)

    def one(x, f):
        xf = x.astype(jnp.float32)
        ff = f.astype(jnp.float32) + eps
        wb = w.reshape((n,) + (1,) * (x.ndim - 1))
        mean = (wb * xf).sum(0)
        fbar = (wb * ff).sum(0)
        corr = (wb * (ff / fbar - 1.0) * (xf - mean)).sum(0)
        merged = mean + corr
        return jnp.broadcast_to(merged, x.shape).astype(x.dtype)

    return jax.tree.map(one, stacked, fishers)


def merge(stacked, method: str, *, W=None, fishers=None, weights=None):
    if method in ("mean", "fedavg"):
        if W is None:
            raise ValueError("mean/fedavg merges need a mixing matrix W")
        return mix(stacked, W)
    if method == "fisher":
        if fishers is None:
            raise ValueError("fisher merge needs fisher estimates")
        return fisher_merge(stacked, fishers)
    if method == "gradmatch":
        if fishers is None:
            raise ValueError("gradmatch merge needs fisher estimates")
        return gradmatch_merge(stacked, fishers, weights)
    raise ValueError(f"unknown merge {method!r}")
