from repro.checkpointing.io import load_metadata, load_pytree, save_json, save_pytree  # noqa: F401
