"""Msgpack pytree checkpoints (per swarm node), offline-friendly.

Layout: one ``<name>.msgpack`` file holding {treedef-paths: (dtype, shape,
bytes)}. Restores exactly (dtype + shape verified). Swarm trainers save one
checkpoint per node plus the sync log.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {"leaves": _flatten(tree), "metadata": metadata or {}}
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = payload["leaves"]

    def restore(p, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        entry = leaves[key]
        arr = np.frombuffer(entry["data"], dtype=entry["dtype"]).reshape(entry["shape"])
        if list(np.asarray(leaf).shape) != entry["shape"]:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{np.asarray(leaf).shape} vs {entry['shape']}")
        return jnp.asarray(arr)

    return jax.tree_util.tree_map_with_path(restore, like)


def load_metadata(path: str) -> dict:
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read(), raw=False)["metadata"]


def save_json(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=float)
