"""Msgpack pytree checkpoints (per swarm node or whole-session), offline-friendly.

Layout: one ``<name>.msgpack`` file holding {keypath: (dtype, shape, bytes)}.
Restores exactly (dtype + shape verified). Swarm trainers save one checkpoint
per node plus the sync log; `core.session.SwarmSession` saves its full
stacked `SwarmState` (params, opt state, strategy stats, membership mask,
rng, counters) as one tree.

Keys are `jax.tree_util.keystr` key paths (e.g. ``['a'][0].params``), which
disambiguate container kinds: a dict key ``"0"`` (``['0']``) and a sequence
index 0 (``[0]``) — or a dict key ``"a/b"`` vs nested ``a → b`` — used to
serialize to the same string under the old ``"/"``-joined scheme and silently
collide. Legacy checkpoints are still readable: the loader falls back to the
old key format per leaf.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _key(path) -> str:
    """Unambiguous keypath string (keystr distinguishes dict/seq/attr keys)."""
    return jax.tree_util.keystr(path)


def _legacy_key(path) -> str:
    """The pre-collision-fix key format (kept for reading old checkpoints)."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _key(path)
        if key in flat:
            raise ValueError(f"duplicate checkpoint key {key!r}")
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {"leaves": _flatten(tree), "metadata": metadata or {}}
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = payload["leaves"]

    def restore(p, leaf):
        key = _key(p)
        entry = leaves.get(key)
        if entry is None:  # legacy checkpoint written with "/"-joined keys
            entry = leaves[_legacy_key(p)]
        arr = np.frombuffer(entry["data"], dtype=entry["dtype"]).reshape(entry["shape"])
        if list(np.asarray(leaf).shape) != entry["shape"]:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{np.asarray(leaf).shape} vs {entry['shape']}")
        out = jnp.asarray(arr)
        # restore onto the template's placement: a mesh-sharded template
        # (gossip-backend params / EF wire state) gets its shards back
        # instead of a replicated copy on the default device. Single-device
        # templates stay UNCOMMITTED so jit remains free to reshard them
        # onto whatever mesh the restored session computes on.
        if (isinstance(leaf, jax.Array)
                and isinstance(leaf.sharding, jax.sharding.NamedSharding)):
            try:
                out = jax.device_put(out, leaf.sharding)
            except (ValueError, RuntimeError):  # template mesh unavailable
                pass
        return out

    return jax.tree_util.tree_map_with_path(restore, like)


def load_metadata(path: str) -> dict:
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read(), raw=False)["metadata"]


def save_json(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=float)
