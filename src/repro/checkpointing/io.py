"""Msgpack pytree checkpoints (per swarm node or whole-session), offline-friendly.

Layout: one ``<name>.msgpack`` file holding {keypath: (dtype, shape, bytes)}.
Restores exactly (dtype + shape verified). Swarm trainers save one checkpoint
per node plus the sync log; `core.session.SwarmSession` saves its full
stacked `SwarmState` (params, opt state, strategy stats, membership mask,
rng, counters) as one tree.

Keys are `jax.tree_util.keystr` key paths (e.g. ``['a'][0].params``), which
disambiguate container kinds: a dict key ``"0"`` (``['0']``) and a sequence
index 0 (``[0]``) — or a dict key ``"a/b"`` vs nested ``a → b`` — used to
serialize to the same string under the old ``"/"``-joined scheme and silently
collide. Legacy checkpoints are still readable: the loader falls back to the
old key format per leaf.

Durability (docs/faults.md): writes are ATOMIC — the payload lands in a
temp file in the destination directory, is fsynced, and is `os.replace`d
over the target, so a crash/preempt mid-save leaves either the previous
checkpoint or the new one, never a torn file. Reads and writes retry
transient ``OSError`` s with bounded backoff (`repro.faults.retry`); a
file that is truncated or not a checkpoint raises a clear ``ValueError``
instead of a msgpack stack trace.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.faults.retry import with_retry


def _key(path) -> str:
    """Unambiguous keypath string (keystr distinguishes dict/seq/attr keys)."""
    return jax.tree_util.keystr(path)


def _legacy_key(path) -> str:
    """The pre-collision-fix key format (kept for reading old checkpoints)."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _key(path)
        if key in flat:
            raise ValueError(f"duplicate checkpoint key {key!r}")
        arr = np.asarray(leaf)
        flat[key] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    return flat


def _atomic_write_bytes(path: str, blob: bytes) -> None:
    """Write-all-or-nothing: temp file in the SAME directory (so the final
    rename never crosses a filesystem), flush + fsync, then `os.replace`
    over the destination. Readers only ever observe a complete file."""
    directory = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=directory,
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {"leaves": _flatten(tree), "metadata": metadata or {}}
    blob = msgpack.packb(payload, use_bin_type=True)
    with_retry(lambda: _atomic_write_bytes(path, blob), retry_on=(OSError,),
               describe=f"checkpoint write {path!r}")


def _read_payload(path: str) -> dict:
    """Read + decode a checkpoint file with transient-IO retry and a clear
    error for truncated/corrupt/non-checkpoint content."""
    def read() -> bytes:
        with open(path, "rb") as f:
            return f.read()

    # raise_last so a genuine FileNotFoundError surfaces as itself (not
    # wrapped in RetryError) after the bounded attempts
    blob = with_retry(read, retry_on=(OSError,), raise_last=True,
                      describe=f"checkpoint read {path!r}")
    try:
        payload = msgpack.unpackb(blob, raw=False)
    except (msgpack.exceptions.UnpackException, ValueError, KeyError,
            TypeError) as exc:
        raise ValueError(
            f"corrupt or truncated checkpoint {path!r}: not a complete "
            f"msgpack payload ({type(exc).__name__}: {exc}). Writes are "
            "atomic (temp-file + os.replace), so a torn file usually means "
            "a partial copy or an interrupted legacy writer") from exc
    if not isinstance(payload, dict) or "leaves" not in payload \
            or "metadata" not in payload:
        raise ValueError(
            f"corrupt or truncated checkpoint {path!r}: decoded payload is "
            "missing the leaves/metadata envelope")
    return payload


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype checked)."""
    leaves = _read_payload(path)["leaves"]

    def restore(p, leaf):
        key = _key(p)
        entry = leaves.get(key)
        if entry is None:  # legacy checkpoint written with "/"-joined keys
            entry = leaves[_legacy_key(p)]
        arr = np.frombuffer(entry["data"], dtype=entry["dtype"]).reshape(entry["shape"])
        if list(np.asarray(leaf).shape) != entry["shape"]:
            raise ValueError(f"shape mismatch at {key}: "
                             f"{np.asarray(leaf).shape} vs {entry['shape']}")
        out = jnp.asarray(arr)
        # restore onto the template's placement: a mesh-sharded template
        # (gossip-backend params / EF wire state) gets its shards back
        # instead of a replicated copy on the default device. Single-device
        # templates stay UNCOMMITTED so jit remains free to reshard them
        # onto whatever mesh the restored session computes on.
        if (isinstance(leaf, jax.Array)
                and isinstance(leaf.sharding, jax.sharding.NamedSharding)):
            try:
                out = jax.device_put(out, leaf.sharding)
            except (ValueError, RuntimeError):  # template mesh unavailable
                pass
        return out

    return jax.tree_util.tree_map_with_path(restore, like)


def load_metadata(path: str) -> dict:
    return _read_payload(path)["metadata"]


def save_json(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=float)
