"""Shared pytest config: bound XLA compile time on the CPU-only test runner.

Tier-1 is compile-bound — dozens of jitted programs (per-arch smoke tests,
the swarm engine, SPMD subprocesses) on a small CPU runner — and XLA's CPU
backend spends most of that wall time in optimization passes that don't
matter for tiny test shapes. Backend optimization level 0 halves compile
time; numerics are unchanged (all tests keep their original tolerances).
Set XLA_FLAGS with an explicit --xla_backend_optimization_level to override.

This file must run before the first `import jax` (pytest imports conftest
first), because XLA_FLAGS is read at backend initialization.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_backend_optimization_level" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_backend_optimization_level=0").strip()


def pytest_configure(config):
    # forced-CPU-mesh subprocess tests: CI shards them into a parallel job
    # (`-m spmd` / `-m "not spmd"`); plain `pytest -x -q` runs everything.
    config.addinivalue_line(
        "markers",
        "spmd: forced-CPU-mesh subprocess tests (shardable into a parallel "
        "CI job)")


def pytest_collection_modifyitems(config, items):
    # CI runs the suite as two marker shards. Evaluate the exact expressions
    # the workflow passes and assert they partition the collected suite —
    # a test matching neither (or both) would silently drop out of CI.
    # Only meaningful on an unfiltered collection (no -m/-k narrowing).
    if config.option.markexpr or config.option.keyword:
        return
    from _pytest.mark.expression import Expression

    shard_a = Expression.compile("spmd")
    shard_b = Expression.compile("not spmd")
    for item in items:
        names = {m.name for m in item.iter_markers()}
        in_a = shard_a.evaluate(names.__contains__)
        in_b = shard_b.evaluate(names.__contains__)
        assert in_a != in_b, (
            f"{item.nodeid}: markers {sorted(names)} place the test in "
            f"{'both CI shards' if in_a else 'neither CI shard'} "
            "(`-m spmd` / `-m \"not spmd\"`) — fix its markers")
